"""Cross-network surrogate-transfer benchmark -> ``BENCH_transfer.json``.

Measures the headline of ``repro.compiler.surrogate_store``: how many
*new* oracle measurements a network co-optimization needs to reach a
target latency when its GBT surrogates start cold vs warm-started from a
*different* zoo network's training rows (equal search budget, separate
record files — only surrogate knowledge moves).

Per ``source->target`` pair:

* ``cold``        — netopt on the target, everything from scratch;
* ``source``      — netopt on the source with ``--save-surrogates``;
* ``transferred`` — netopt on the target, ``--warm-from`` the source
  store (GBT-ranked seed candidates + informed MAPPO from episode one);
* ``warm-self``   — the transferred run re-run against its own records
  AND its own store: must replay with **0** new measurements (the
  own-network row exclusion keeps transfer and replay orthogonal).

The sample-efficiency readout is ``NetworkReport.measurements_to(
cold_best)``: the cumulative measurement count at which each run first
matched the cold run's final best.

    PYTHONPATH=src python benchmarks/transfer_runs.py \
        [--pairs vgg-11:resnet-18] [--json-out BENCH_transfer.json]
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time
from typing import Dict, List, Optional, Tuple

from repro.compiler.netopt import NetOptConfig, NetworkCoOptimizer
from repro.compiler.surrogate_store import SurrogateStore
from repro.compiler.zoo import get_network
from repro.core import mappo
from repro.core.tuner import TunerConfig

from tuning_runs import write_bench_artifact  # noqa: E402 (sibling module)

# The headline pair is pod -> pod: the pod proxy's optimum geometry is
# *interior* (TP collectives punish over-sharding), so the cold outer
# search only finds it in a late CS round while a transferred hardware
# surrogate ranks it into the first proposed seed slot.  The conv pair
# is kept as the honest contrast: the conv analytical optimum tends to
# be a guaranteed seed (largest feasible geometry), so there is little
# candidate-ordering advantage left to transfer.
DEFAULT_PAIRS = ("pod-cells-4b:pod-cells", "vgg-11:resnet-18")


def bench_tuner() -> TunerConfig:
    return TunerConfig(iteration_opt=4, b_measure=8, episodes_per_iter=2,
                       mappo=mappo.MappoConfig(n_steps=32, n_envs=8),
                       gbt_rounds=16)


def bench_netcfg(layer_budget: int, refine_budget: int) -> NetOptConfig:
    # refine_budget defaults to 0 here: the refinement pass re-runs the
    # winner at a deeper budget at the very end of *both* runs, which
    # only moves the target to the final trace row for everyone.  With
    # it off, measurements_to() reads pure candidate-ordering sample
    # efficiency — what the transferred hardware surrogate changes.
    return NetOptConfig(seed_candidates=3, hw_rounds=2, hw_per_round=2,
                        layer_budget=layer_budget,
                        refine_budget=refine_budget, tuner=bench_tuner())


def _run(tasks, ncfg, name: str, records: Optional[str],
         surrogates: Optional[SurrogateStore], max_tasks: int):
    tasks = list(tasks)[:max_tasks] if max_tasks else list(tasks)
    return NetworkCoOptimizer(tasks, ncfg, records=records, name=name,
                              surrogates=surrogates).run()


def transfer_pair(source: str, target: str, ncfg: NetOptConfig,
                  workdir: str, max_tasks: int) -> Dict[str, float]:
    """One pair's metrics (flat floats, prefixed by the caller)."""
    src_net, tgt_net = get_network(source), get_network(target)
    store_path = os.path.join(workdir, f"{source}.surr.jsonl")
    tgt_store_path = os.path.join(workdir, f"{source}-{target}.surr.jsonl")
    tgt_records = os.path.join(workdir, f"{target}.warm.records.jsonl")

    cold = _run(tgt_net.tasks, ncfg, tgt_net.name,
                os.path.join(workdir, f"{target}.cold.records.jsonl"),
                None, max_tasks)
    src = _run(src_net.tasks, ncfg, src_net.name,
               os.path.join(workdir, f"{source}.records.jsonl"),
               SurrogateStore(store_path), max_tasks)
    # the transferred run accumulates into its own store (seeded with the
    # source rows) so the warm-self leg below warms from the same file
    tgt_store = SurrogateStore(tgt_store_path)
    tgt_store.merge_from(store_path)
    warm = _run(tgt_net.tasks, ncfg, tgt_net.name, tgt_records,
                tgt_store, max_tasks)
    self_rerun = _run(tgt_net.tasks, ncfg, tgt_net.name, tgt_records,
                      SurrogateStore(tgt_store_path), max_tasks)

    cold_best = cold.network_latency
    warm_to_target = warm.measurements_to(cold_best)
    cold_to_best = cold.measurements_to(cold_best)
    print(f"{source} -> {target}: cold best {cold_best * 1e6:.1f} us in "
          f"{cold_to_best} meas; transferred reached it in "
          f"{warm_to_target} meas (final {warm.network_latency * 1e6:.1f} "
          f"us, {warm.surrogates.get('warm_hw_rows', 0)} hw / "
          f"{warm.surrogates.get('warm_sw_rows', 0)} sw rows warm); "
          f"warm-self replayed with {self_rerun.total_measurements} new",
          flush=True)
    out = {
        "cold_best_latency_s": cold_best,
        "cold_measurements": float(cold.total_measurements),
        "cold_measurements_to_best": float(cold_to_best),
        "transfer_best_latency_s": warm.network_latency,
        "transfer_measurements": float(warm.total_measurements),
        "transfer_measurements_to_cold_best": (
            float(warm_to_target) if warm_to_target is not None else -1.0),
        "transfer_warm_hw_rows": float(
            warm.surrogates.get("warm_hw_rows", 0)),
        "transfer_warm_sw_rows": float(
            warm.surrogates.get("warm_sw_rows", 0)),
        "warm_self_new_measurements": float(self_rerun.total_measurements),
    }
    if warm_to_target is not None and cold_to_best:
        out["transfer_measurement_saving_frac"] = \
            1.0 - warm_to_target / cold_to_best
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pairs", nargs="*", default=list(DEFAULT_PAIRS),
                    metavar="SRC:TGT",
                    help="zoo network pairs (default: "
                         + " ".join(DEFAULT_PAIRS) + ")")
    ap.add_argument("--max-tasks", type=int, default=4,
                    help="cap tasks per network (0 = all; default 4 keeps "
                         "the bench minutes-scale)")
    ap.add_argument("--layer-budget", type=int, default=12)
    ap.add_argument("--refine-budget", type=int, default=0)
    ap.add_argument("--json-out", default="BENCH_transfer.json",
                    metavar="BENCH_transfer.json")
    ap.add_argument("--workdir", default=None,
                    help="keep records/stores here (default: tempdir)")
    args = ap.parse_args()

    pairs: List[Tuple[str, str]] = []
    for spec in args.pairs:
        source, _, target = spec.partition(":")
        if not target or source == target:
            raise SystemExit(f"--pairs wants SRC:TGT with SRC != TGT, "
                             f"got {spec!r}")
        pairs.append((source, target))

    ncfg = bench_netcfg(args.layer_budget, args.refine_budget)
    workdir = args.workdir or tempfile.mkdtemp(prefix="transfer-bench-")
    t0 = time.perf_counter()
    metrics: Dict[str, float] = {}
    for source, target in pairs:
        pair = transfer_pair(source, target, ncfg, workdir, args.max_tasks)
        metrics.update({f"{source}->{target}/{k}": v
                        for k, v in pair.items()})
    metrics["wall_time_s"] = time.perf_counter() - t0
    write_bench_artifact(
        args.json_out, "surrogate_transfer", metrics,
        config={"pairs": [f"{s}:{t}" for s, t in pairs],
                "max_tasks": args.max_tasks,
                "layer_budget": args.layer_budget,
                "refine_budget": args.refine_budget,
                "seed_candidates": ncfg.seed_candidates,
                "hw_rounds": ncfg.hw_rounds,
                "hw_per_round": ncfg.hw_per_round})


if __name__ == "__main__":
    main()
