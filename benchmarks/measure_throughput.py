"""Measurement-throughput micro-bench for ``repro.compiler.executor``.

Runs the same cold-cache measurement batch through a ``SettingsOracle``
backed by the in-process ``SerialExecutor`` and by ``SubprocessExecutor``
pools of 1/2/4 workers, against a deterministic stub oracle that sleeps
``--delay`` seconds per measurement (modelling the tens-of-seconds SPMD
compile at CI-friendly scale).  Reports measurements/sec per backend so
the fan-out speedup is demonstrable without TPUs:

    PYTHONPATH=src python benchmarks/measure_throughput.py
    PYTHONPATH=src python benchmarks/measure_throughput.py \
        --delay 0.5 --n 48 --workers 1,2,4,8 --json artifacts/throughput.json

``--remote N[,M...]`` benchmarks the remote measurement fabric instead:
for each fleet size it spawns that many loopback worker daemons
(``python -m repro.compiler.executor.worker``), drives them through a
``RemoteExecutor``, and reports meas/sec the same way — the TCP tax at
its worst (localhost round-trips, zero-cost oracle); ``--bench-json
BENCH_remote.json`` additionally emits the standardized bench artifact:

    PYTHONPATH=src python benchmarks/measure_throughput.py \
        --remote 1,2,4 --bench-json BENCH_remote.json

Worker pools (and daemons) are pre-spawned outside the timed region (a
session reuses one pool across every Confidence-Sampling batch, so spawn
cost amortizes away; the per-batch measurement rate is the number that
gates optimization time).

NOTE: all heavy imports live inside ``main`` on purpose.  Spawned workers
re-import this script as ``__mp_main__``, and a module-level jax/numpy
import would make every stub worker pay seconds of interpreter start-up —
exactly the overhead the executor package's import-light rule exists to
avoid.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))


def distinct_configs(space, n: int):
    """First ``n`` configs in mixed-radix order — distinct, deterministic,
    and identical for every backend."""
    import numpy as np
    radices = [len(c) for c in space.choices]
    out = np.zeros((n, len(radices)), np.int64)
    for i in range(n):
        rem = i
        for k, r in enumerate(radices):
            out[i, k] = rem % r
            rem //= r
    return out


def run_once(space, configs, executor, label: str, spec=None) -> dict:
    import numpy as np
    from repro.compiler.oracle import SettingsOracle
    oracle = SettingsOracle(space, fn=None, executor=executor,
                            task=f"throughput/{label}", own_executor=True,
                            worker_spec=spec)
    t0 = time.perf_counter()
    lat, _ = oracle.measure(configs)
    wall = time.perf_counter() - t0
    oracle.close()
    assert oracle.stats()["failures"] == 0, oracle.stats()
    return {"backend": label, "wall_s": wall,
            "meas_per_s": len(configs) / wall,
            "mean_latency": float(np.mean(lat))}


def run_remote(space, configs, fleet_sizes, delay_s: float) -> list:
    """meas/sec against N loopback daemons per fleet size: spawn the
    daemons (outside the timed region, like pool pre-spawn), point one
    ``RemoteExecutor`` at all of them, run the same batch."""
    from repro.compiler.executor import (RemoteExecutor, WorkerSpec,
                                         spawn_daemon)

    spec = WorkerSpec(factory="repro.compiler.executor.stub:make_stub",
                      kwargs={"delay_s": delay_s})
    rows = []
    for n_daemons in fleet_sizes:
        procs, endpoints = [], []
        try:
            for _ in range(n_daemons):
                proc, ep = spawn_daemon(slots=1)
                procs.append(proc)
                endpoints.append(ep)
            ex = RemoteExecutor(endpoints)
            row = run_once(space, configs, ex, f"remote[{n_daemons}]",
                           spec=spec)
            rows.append(row)
        finally:
            for proc in procs:
                proc.terminate()
            for proc in procs:
                proc.wait(timeout=10)
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--delay", type=float, default=0.2,
                    help="stub oracle seconds per measurement")
    ap.add_argument("--n", type=int, default=32,
                    help="measurements per batch (cold cache)")
    ap.add_argument("--workers", default="1,2,4",
                    help="comma-separated subprocess pool sizes")
    ap.add_argument("--remote", default=None, metavar="N[,M...]",
                    help="benchmark the remote fabric against these "
                         "loopback daemon fleet sizes instead of local "
                         "subprocess pools")
    ap.add_argument("--json", default=None, help="write results JSON here")
    ap.add_argument("--bench-json", default=None,
                    metavar="BENCH_remote.json",
                    help="with --remote: also write the standardized "
                         "bench artifact (write_bench_artifact)")
    args = ap.parse_args()
    if args.bench_json and not args.remote:
        ap.error("--bench-json is the remote-fabric artifact; it needs "
                 "--remote N[,M...]")

    from repro.compiler.executor import (SerialExecutor, SubprocessExecutor,
                                         WorkerSpec)
    from repro.compiler.executor.stub import make_stub
    from repro.core.shard_space import ShardSpace

    space = ShardSpace.for_cell("qwen2-1.5b", "train_4k", None, n_devices=256)
    configs = distinct_configs(space, args.n)
    spec = WorkerSpec(factory="repro.compiler.executor.stub:make_stub",
                      kwargs={"delay_s": args.delay})

    rows = [run_once(space, configs,
                     SerialExecutor(fn=make_stub(delay_s=args.delay)),
                     "serial")]
    if args.remote:
        rows += run_remote(space, configs,
                           [int(x) for x in args.remote.split(",")],
                           args.delay)
    else:
        for w in (int(x) for x in args.workers.split(",")):
            pool = SubprocessExecutor(spec, workers=w)
            pool.start()  # spawn outside the timed region (pool is reused)
            rows.append(run_once(space, configs, pool, f"subprocess[{w}]"))

    base = rows[0]["meas_per_s"]
    print(f"\n{args.n} measurements/batch, {args.delay:.2f}s stub oracle")
    print(f"{'backend':16s} {'wall_s':>8s} {'meas/s':>8s} {'speedup':>8s}")
    for r in rows:
        r["speedup_vs_serial"] = r["meas_per_s"] / base
        print(f"{r['backend']:16s} {r['wall_s']:8.2f} "
              f"{r['meas_per_s']:8.2f} {r['speedup_vs_serial']:7.2f}x")

    # parity: every backend must agree on the (deterministic) stub values
    assert len({round(r["mean_latency"], 12) for r in rows}) == 1, rows

    if args.json:
        d = os.path.dirname(args.json)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.json, "w") as f:
            json.dump({"delay_s": args.delay, "n": args.n, "runs": rows},
                      f, indent=1)
    if args.bench_json:
        # standardized bench artifact, same convention as BENCH_netopt/
        # BENCH_hetero (sibling import: benchmarks/ is not a package)
        sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
        from tuning_runs import write_bench_artifact
        metrics = {"serial_meas_per_s": rows[0]["meas_per_s"]}
        for r in rows[1:]:
            n_d = r["backend"].split("[")[1].rstrip("]")
            metrics[f"remote{n_d}_meas_per_s"] = r["meas_per_s"]
            metrics[f"remote{n_d}_speedup_vs_serial"] = \
                r["speedup_vs_serial"]
        write_bench_artifact(
            args.bench_json, "remote_throughput", metrics,
            config={"delay_s": args.delay, "n": args.n,
                    "fleet_sizes": [int(x) for x in args.remote.split(",")],
                    "transport": "tcp-loopback", "slots_per_daemon": 1})
    return 0


if __name__ == "__main__":
    sys.exit(main())
