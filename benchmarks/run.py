"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``us_per_call`` is the
simulated inference latency (the paper's Table-6 metric) where applicable,
wall-clock tuning time for Fig. 6, and the derived column carries the
paper-comparable ratio.

    PYTHONPATH=src python -m benchmarks.run             # all benchmarks
    PYTHONPATH=src python -m benchmarks.run table6 fig7 # subset
    REPRO_PAPER=1 ...                                   # full Table-4 budget
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List

import numpy as np

from benchmarks import tuning_runs as TR

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


# ------------------------------------------------------------------ table 6

def bench_table6(sweep: Dict):
    """Mean inference times per framework on the tunable accelerator
    (Table 6 analog; seconds in the paper, simulated us here)."""
    nets = TR.network_results(sweep)
    for net, r in nets.items():
        for fw in TR.FRAMEWORKS:
            emit(f"table6.{net}.{fw}", r["latency"][fw] * 1e6,
                 "best_simulated_conv_latency_sum")


# ------------------------------------------------------------------- fig 5

def bench_fig5(sweep: Dict):
    """Throughput relative to AutoTVM (Fig. 5 analog)."""
    nets = TR.network_results(sweep)
    ratios = []
    for net, r in nets.items():
        base = r["latency"]["autotvm"]
        for fw in ("chameleon", "arco"):
            ratio = base / r["latency"][fw]
            if fw == "arco":
                ratios.append(ratio)
            emit(f"fig5.{net}.{fw}_over_autotvm",
                 r["latency"][fw] * 1e6, f"throughput_ratio={ratio:.3f}")
    emit("fig5.geomean.arco_over_autotvm", 0.0,
         f"throughput_ratio={float(np.exp(np.mean(np.log(ratios)))):.3f}"
         f" (paper: mean 1.17x, up to 1.38x)")


# ------------------------------------------------------------------- fig 6

def bench_fig6(sweep: Dict):
    """Optimization (tuning) time per framework (Fig. 6 analog)."""
    nets = TR.network_results(sweep)
    for net, r in nets.items():
        base = r["tuning_wall_s"]["autotvm"]
        for fw in TR.FRAMEWORKS:
            w = r["tuning_wall_s"][fw]
            emit(f"fig6.{net}.{fw}", w * 1e6,
                 f"tuning_speedup_vs_autotvm={base / w:.3f}")


# ------------------------------------------------------------------- fig 7

def bench_fig7(sweep: Dict):
    """Convergence: best achieved GFLOPS vs measurement count for the
    heaviest ResNet-18 conv task (Fig. 7 analog)."""
    from repro.core.task import conv_tasks
    from repro.hw.analytical import conv2d_gflops
    tasks = conv_tasks("resnet-18")
    heavy = max(tasks, key=lambda t: t.space.workload["ci"]
                * t.space.workload["co"])
    key = json.dumps(sorted(heavy.space.workload.items()))
    entry = sweep["tasks"][key]
    wl = heavy.space.workload
    for fw in TR.FRAMEWORKS:
        hist = entry[fw]["history"]
        for count, best, _ in hist[:: max(len(hist) // 6, 1)]:
            emit(f"fig7.{fw}.n{count}", best * 1e6,
                 f"gflops={conv2d_gflops(wl, best):.1f}")
        n90 = _measurements_to_reach(entry[fw], 1.10)
        emit(f"fig7.{fw}.to_within_10pct", 0.0, f"measurements={n90}")


def _measurements_to_reach(run: Dict, slack: float) -> int:
    target = run["best_latency"] * slack
    for count, best, _ in run["history"]:
        if best <= target:
            return count
    return run["n_measurements"]


# ------------------------------------------------------------------- fig 4

def bench_fig4():
    """Measured-configuration quality over time, with vs without CS
    (Fig. 4 analog) — run fresh (needs the CS ablation flag)."""
    from repro.compiler import Session, TuningTask
    from repro.core.design_space import DesignSpace
    wl = dict(b=1, h=14, w=14, ci=256, co=256, kh=3, kw=3, stride=1, pad=1)
    task = TuningTask.from_space("fig4", DesignSpace.for_conv2d(wl))
    cfg = TR.tuner_config()
    r_cs = Session(task, tuner=cfg, use_cs=True).run().single
    r_nocs = Session(task, tuner=cfg, use_cs=False).run().single
    for tag, r in (("with_cs", r_cs), ("without_cs", r_nocs)):
        lats = np.asarray([l for _, l in r.measurements])
        lats = lats[np.isfinite(lats) & (lats < 1e6)]
        half = len(lats) // 2
        grav = "yes" if lats[half:].mean() < lats[:half].mean() else "no"
        emit(f"fig4.{tag}.first_half_mean", float(lats[:half].mean()) * 1e6,
             f"n={half}")
        emit(f"fig4.{tag}.second_half_mean",
             float(lats[half:].mean()) * 1e6, f"gravitates={grav}")
        emit(f"fig4.{tag}.best", r.best_latency * 1e6,
             f"n_measured={r.n_measurements}")


# ---------------------------------------------------------------- roofline

def bench_roofline():
    """Roofline terms per dry-run artifact (EXPERIMENTS.md section source)."""
    art_dir = os.environ.get("REPRO_DRYRUN_ART", "artifacts/dryrun")
    if not os.path.isdir(art_dir):
        emit("roofline.skipped", 0.0, f"no artifacts under {art_dir}")
        return
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES
    from repro.hw import roofline as RL
    for fname in sorted(os.listdir(art_dir)):
        if not fname.endswith(".json"):
            continue
        with open(os.path.join(art_dir, fname)) as f:
            art = json.load(f)
        if art.get("status") != "ok" or "weighted" not in art:
            continue
        cfg = get_config(art["arch"])
        cell = SHAPES[art["shape"]]
        mesh = {p.split("=")[0].strip(): int(p.split("=")[1])
                for p in art["mesh_desc"].split(" x ")}
        r = RL.analyze_cell(cfg, cell.kind, cell.seq, cell.global_batch,
                            mesh, art)
        n_dev = int(np.prod(list(mesh.values())))
        frac = RL.roofline_fraction(r, n_dev=n_dev)
        res = RL.hbm_residency(cfg, cell.kind, cell.seq, cell.global_batch,
                               mesh)
        emit(f"roofline.{art['arch']}.{art['shape']}.{art['mesh']}",
             r.step_s * 1e6,
             f"dominant={r.dominant};comp={r.compute_s:.2e};"
             f"mem={r.memory_s:.2e};coll={r.collective_s:.2e};"
             f"useful_ratio={r.usefulness:.2f};roofline_frac={frac:.3f};"
             f"hbm_gib={res / 2**30:.1f}")


BENCHES = {
    "table6": lambda sweep: bench_table6(sweep),
    "fig5": lambda sweep: bench_fig5(sweep),
    "fig6": lambda sweep: bench_fig6(sweep),
    "fig7": lambda sweep: bench_fig7(sweep),
    "fig4": lambda sweep: bench_fig4(),
    "roofline": lambda sweep: bench_roofline(),
}


def main() -> None:
    names = sys.argv[1:] or list(BENCHES)
    needs_sweep = any(n in ("table6", "fig5", "fig6", "fig7")
                      for n in names)
    sweep = TR.run_sweep() if needs_sweep else None
    print("name,us_per_call,derived", flush=True)
    for n in names:
        if n not in BENCHES:
            print(f"unknown benchmark {n}; have {list(BENCHES)}")
            continue
        BENCHES[n](sweep)


if __name__ == "__main__":
    main()
